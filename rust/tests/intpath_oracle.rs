//! Oracle tests for the plan-based integer pipeline
//! (`quant::plan::QuantPlan` + `sim::intpath::PlanRunner`):
//!
//! * **first-layer bit-identity** — on the first conv layer the plan
//!   path (weights quantized at build time, input quantized once) must
//!   reproduce the per-call `conv2d_quant` reference EXACTLY, for every
//!   `KernelStrategy`, both kernels and both serving widths: the same
//!   shared exponent (§3.1) drives both paths, so the integer operands
//!   — and therefore the i32 accumulators — are the same integers;
//! * **cross-strategy whole-model identity** — the whole stack (conv
//!   chain AND the integer dense head, i64-exact with a single pow2
//!   logit rescale) agrees across Naive/Tiled/Simd/Winograd/Auto bit
//!   for bit, logits included; on mult plans the Winograd strategy
//!   takes the exact transform-domain path on every 3x3/stride-1
//!   layer, so its rows double as the transform's whole-model oracle;
//! * **plan vs per-call tracking** — the compiled plan serves logits
//!   close to the per-call experiment path and the f32 reference at
//!   int16/int8.

use addernet::quant::plan::{requant_shift, QuantPlan};
use addernet::quant::{Calibration, LayerCalib, Mode};
use addernet::report::quantrep;
use addernet::sim::functional::{self, conv2d_quant_with, synth_params, Arch,
                                ConvW, ExecMode, KernelStrategy, QConvW,
                                QDenseW, QuantCfg, Runner, SimKernel, Tensor};
use addernet::sim::intpath::{self, IntTensor, PlanRunner};
use addernet::util::XorShift64;

const STRATEGIES: [KernelStrategy; 5] = [
    KernelStrategy::Naive,
    KernelStrategy::Tiled,
    KernelStrategy::Simd,
    KernelStrategy::Winograd,
    KernelStrategy::Auto,
];

fn rand_tensor(rng: &mut XorShift64, shape: (usize, usize, usize, usize),
               scale: f32) -> Tensor {
    let (n, h, w, c) = shape;
    Tensor::new(shape, (0..n * h * w * c).map(|_| rng.next_f32_sym(scale)).collect())
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol, "{what}: element {i}: {x} vs {y} (tol {tol})");
    }
}

fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0f32, |m, &v| m.max(v.abs()))
}

/// The plan's first conv layer, executed on the raw integer engine,
/// must be bit-identical to the per-call `conv2d_quant` reference:
/// identical operands on the shared grid, identical i32 accumulators,
/// identical dequantization scale.
#[test]
fn first_layer_bit_identical_to_percall_reference() {
    let params = synth_params(Arch::Lenet5, 42);
    let mut rng = XorShift64::new(11);
    let x = rand_tensor(&mut rng, (2, 32, 32, 1), 1.0);
    for kind in [SimKernel::Adder, SimKernel::Mult] {
        let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, kind, 16);
        // int16 only for the adder kernel: its accumulator is provably
        // i32-bounded (|acc| <= 2*qmax*K), while int16 MULT products
        // can overflow the widened accumulator on large layers.
        let widths: &[u32] = match kind {
            SimKernel::Adder => &[8, 16],
            SimKernel::Mult => &[8],
        };
        for &bits in widths {
            let cfg = QuantCfg { bits, mode: Mode::SharedScale };
            let plan = QuantPlan::build(&params, Arch::Lenet5, kind, cfg, &calib)
                .unwrap();
            let lp = &plan.convs["conv1"];
            assert_eq!(plan.input_exp, lp.in_exp);
            let (ws, wd) = &params["conv1/conv_w"];
            let cw = ConvW { data: wd, kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3] };
            let lc = &calib["conv1"];
            let scale = (lp.acc_exp as f32).exp2();
            for strat in STRATEGIES {
                let want = conv2d_quant_with(strat, &x, &cw, lp.stride,
                                             lp.padding, kind, cfg, lc);
                let xq = intpath::quantize_input(&x, plan.input_exp, bits);
                let qw = QConvW { data: &lp.wq, kh: lp.kh, kw: lp.kw,
                                  cin: lp.cin, cout: lp.cout };
                let (acc, oshape) = functional::conv2d_int_with(
                    strat, &xq.data, xq.shape, &qw, lp.stride, lp.padding, kind);
                assert_eq!(oshape, want.shape,
                           "{kind:?} int{bits} [{}]", strat.label());
                for (i, (&a, &w)) in acc.iter().zip(&want.data).enumerate() {
                    let got = a as f32 * scale;
                    assert!(got == w,
                            "{kind:?} int{bits} [{}] element {i}: plan {got} \
                             vs per-call {w}", strat.label());
                }
            }
        }
    }
}

/// Whole-model plan execution is BIT-identical across every kernel
/// strategy: the conv stack is i32-exact, the dense head accumulates
/// exactly in i64, and the final logit rescale is one pow2 move — so
/// with the head now integer there is no f32 round-off anywhere to hide
/// a strategy divergence behind.
#[test]
fn whole_model_plan_identical_across_strategies() {
    for (arch, seed) in [(Arch::Lenet5, 3u64), (Arch::Resnet8, 5)] {
        let params = synth_params(arch, seed);
        let (calib, _) = quantrep::calibrate(&params, arch, SimKernel::Adder, 16);
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg, &calib)
            .unwrap();
        let mut rng = XorShift64::new(21);
        let x = rand_tensor(&mut rng, (2, 32, 32, 1), 1.0);
        let mut logits = Vec::new();
        for strat in STRATEGIES {
            let r = PlanRunner { plan: &plan, strategy: strat };
            let y = r.forward(&x);
            assert_eq!(y.shape, (2, 1, 1, 10), "{arch:?} [{}]", strat.label());
            assert!(y.data.iter().all(|v| v.is_finite()));
            logits.push(y.data);
        }
        for (i, l) in logits.iter().enumerate().skip(1) {
            assert_eq!(l, &logits[0],
                       "{arch:?} logits [{}] vs [{}] must be bit-identical",
                       STRATEGIES[i].label(), STRATEGIES[0].label());
        }
    }
}

/// ISSUE-9 acceptance: on MULT int8 plans the Winograd transform path
/// actually engages (every 3x3/stride-1 conv; the shape guard covers
/// the rest) and the whole-model logits stay bit-identical to the row
/// kernels for EVERY servable arch — the transform is exact, not
/// approximately close.
#[test]
fn mult_plans_bit_identical_with_winograd_every_arch() {
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    for arch in Arch::ALL {
        let params = synth_params(arch, 17);
        let calib: Calibration = params.keys()
            .filter_map(|k| k.strip_suffix("/conv_w"))
            .map(|n| (n.to_string(),
                      LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
            .collect();
        let plan = QuantPlan::build(&params, arch, SimKernel::Mult, cfg,
                                    &calib).unwrap();
        let mut rng = XorShift64::new(71);
        let x = rand_tensor(&mut rng, (1, 32, 32, 1), 1.0);
        let simd = PlanRunner { plan: &plan, strategy: KernelStrategy::Simd }
            .forward(&x);
        let wino = PlanRunner { plan: &plan,
                                strategy: KernelStrategy::Winograd }
            .forward(&x);
        assert_eq!(wino.shape, simd.shape, "{arch:?}");
        assert!(wino.data.iter().all(|v| v.is_finite()));
        assert_eq!(wino.data, simd.data,
                   "{arch:?}: winograd mult plan logits must be bit-identical \
                    to simd");
        // pin the naive reference too where it's cheap — lenet5 is the
        // all-fallback case (5x5 convs), resnet8 the all-transform case
        if matches!(arch, Arch::Lenet5 | Arch::Resnet8) {
            let naive = PlanRunner { plan: &plan,
                                     strategy: KernelStrategy::Naive }
                .forward(&x);
            assert_eq!(wino.data, naive.data, "{arch:?}: winograd vs naive");
        }
    }
}

/// Non-trivial LeNet parameters: BN scale/shift chosen so the
/// always-negative adder responses re-center into the ReLU pass-band at
/// BOTH conv layers — real signal flows through the whole int stack
/// instead of the all-zero activations identity-BN synth weights give.
fn lively_lenet_params() -> functional::Params {
    let mut params = synth_params(Arch::Lenet5, 7);
    params.insert("conv1/bn_gamma".into(), (vec![6], vec![0.1; 6]));
    params.insert("conv1/bn_beta".into(), (vec![6], vec![2.0; 6]));
    params.insert("conv2/bn_gamma".into(), (vec![16], vec![0.02; 16]));
    params.insert("conv2/bn_beta".into(), (vec![16], vec![2.5; 16]));
    params
}

/// int16 plan logits track the f32 reference closely, and int8 plan
/// logits track the per-call int8 experiment path: the compiled
/// pipeline preserves the §3.1 accuracy story end-to-end.
#[test]
fn plan_logits_track_f32_and_percall_paths() {
    let params = lively_lenet_params();
    let n = 16usize;
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, n);
    // the SAME images the calibration pass saw: ranges cover them
    let b = addernet::data::eval_set(n, 7);
    let x = Tensor::new((n, 32, 32, 1), b.images);

    let mut f32_runner = Runner {
        params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
        strategy: KernelStrategy::Auto, mode: ExecMode::F32,
        calib: None, observe: None,
    };
    let f32_logits = f32_runner.forward(&x);
    let scale = max_abs(&f32_logits.data).max(1.0);

    // int16: the plan path must sit on top of the f32 reference
    let cfg16 = QuantCfg { bits: 16, mode: Mode::SharedScale };
    let plan16 = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                  cfg16, &calib).unwrap();
    let p16 = PlanRunner { plan: &plan16, strategy: KernelStrategy::Auto }
        .forward(&x);
    assert_close(&p16.data, &f32_logits.data, 0.03 * scale, "int16 plan vs f32");

    // int8: plan and per-call approximate f32 with the same grids, so
    // they must stay near each other (and sane vs f32)
    let cfg8 = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let plan8 = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                 cfg8, &calib).unwrap();
    let p8 = PlanRunner { plan: &plan8, strategy: KernelStrategy::Auto }
        .forward(&x);
    let mut percall_runner = Runner {
        params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
        strategy: KernelStrategy::Auto, mode: ExecMode::Quant(cfg8),
        calib: Some(&calib), observe: None,
    };
    let percall = percall_runner.forward(&x);
    assert_close(&p8.data, &percall.data, 0.5 * scale, "int8 plan vs per-call");
    assert_close(&p8.data, &f32_logits.data, 0.75 * scale, "int8 plan vs f32");
}

/// Accuracy through the two quantized paths stays comparable — the
/// `quantplan` report's claim, pinned as a test.
#[test]
fn plan_accuracy_tracks_percall_accuracy() {
    let params = lively_lenet_params();
    let n = 64usize;
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, n);
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let percall = quantrep::quant_accuracy(&params, Arch::Lenet5,
                                           SimKernel::Adder, &calib, cfg, n);
    let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, cfg,
                                &calib).unwrap();
    let b = addernet::data::eval_set(n, 7);
    let x = Tensor::new((n, 32, 32, 1), b.images);
    let pacc = intpath::plan_accuracy(&plan, KernelStrategy::Auto, &x, &b.labels);
    assert!((0.0..=1.0).contains(&pacc));
    assert!((pacc - percall).abs() <= 0.3,
            "plan acc {pacc} drifted from per-call acc {percall}");
}

/// SeparateScale plans also execute (the S7 contrast mode): sane,
/// finite, cross-strategy identical.
#[test]
fn separate_scale_plan_executes() {
    let params = lively_lenet_params();
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, 8);
    let cfg = QuantCfg { bits: 8, mode: Mode::SeparateScale };
    let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, cfg,
                                &calib).unwrap();
    let mut rng = XorShift64::new(33);
    let x = rand_tensor(&mut rng, (1, 32, 32, 1), 1.0);
    let mut logits = Vec::new();
    for strat in STRATEGIES {
        let y = PlanRunner { plan: &plan, strategy: strat }.forward(&x);
        assert!(y.data.iter().all(|v| v.is_finite()));
        logits.push(y.data);
    }
    for l in logits.iter().skip(1) {
        assert_eq!(l, &logits[0], "separate-scale cross-strategy");
    }
}

// ---------------------------------------------------------------------------
// Golden pre/post-refactor equivalence: the graph-driven PlanRunner vs
// a literal transcription of the pre-graph hand-coded integer walk
// ---------------------------------------------------------------------------

/// Residual-net block tables (prefix, has projection shortcut) written
/// out literally — the topology as the pre-graph executor hard-coded it.
const RESNET8_BLOCKS: &[(&str, bool)] = &[
    ("s0b0", false),
    ("s1b0", true),
    ("s2b0", true),
];

const RESNET20_BLOCKS: &[(&str, bool)] = &[
    ("s0b0", false),
    ("s0b1", false),
    ("s0b2", false),
    ("s1b0", true),
    ("s1b1", false),
    ("s1b2", false),
    ("s2b0", true),
    ("s2b1", false),
    ("s2b2", false),
];

/// The pre-graph `PlanRunner::conv_block`, verbatim: requant/clamp the
/// operands, run the strategy-dispatched integer conv, apply folded BN
/// in the DW+2 register.
fn legacy_plan_conv_block(plan: &QuantPlan, strategy: KernelStrategy,
                          name: &str, x: &IntTensor) -> IntTensor {
    let lp = &plan.convs[name];
    let qmax = plan.qmax();
    let xin = if x.exp == lp.in_exp {
        let mut t = x.clone();
        for v in t.data.iter_mut() {
            *v = (*v).clamp(-qmax, qmax);
        }
        t
    } else {
        intpath::shift_to(x, lp.in_exp, qmax)
    };
    let qw = QConvW {
        data: &lp.wq,
        kh: lp.kh,
        kw: lp.kw,
        cin: lp.cin,
        cout: lp.cout,
    };
    let (mut acc, oshape) = functional::conv2d_int_with(
        strategy, &xin.data, xin.shape, &qw, lp.stride, lp.padding, plan.kind);
    let reg_max = plan.qmax() << intpath::HEADROOM_BITS;
    for (i, v) in acc.iter_mut().enumerate() {
        *v = lp.bn.apply(*v, i % lp.cout, reg_max);
    }
    IntTensor { data: acc, shape: oshape, exp: lp.out_exp }
}

/// The hand-coded integer classifier head, a literal transcription of
/// what the graph-driven dense hook does: shift/clamp operands onto the
/// layer's plan grid, run the strategy-dispatched integer dense core,
/// requantize intermediates into the DW+2 register (ReLU between
/// layers), and dequantize the final accumulators off their grid — the
/// requant-to-logits rescale.
fn legacy_head(plan: &QuantPlan, strategy: KernelStrategy, x: &IntTensor,
               names: &[&str]) -> Tensor {
    let qmax = plan.qmax();
    let reg_max = (qmax << intpath::HEADROOM_BITS) as i64;
    let mut t = x.clone();
    for (i, name) in names.iter().enumerate() {
        let dp = &plan.dense[*name];
        let xin = if t.exp == dp.in_exp {
            let mut c = t.clone();
            for v in c.data.iter_mut() {
                *v = (*v).clamp(-qmax, qmax);
            }
            c
        } else {
            intpath::shift_to(&t, dp.in_exp, qmax)
        };
        let n = xin.shape.0;
        let qw = QDenseW { data: &dp.wq, din: dp.din, dout: dp.dout };
        let acc = functional::dense_int_with(strategy, &xin.data, n, &qw,
                                             &dp.bq);
        match dp.out_exp {
            Some(oe) => {
                assert!(i + 1 < names.len(), "{name}: intermediate grid on \
                                              the final dense layer");
                let d = oe - dp.acc_exp;
                let data = acc.iter()
                    .map(|&a| requant_shift(a, d)
                        .clamp(-reg_max, reg_max) as i32)
                    .collect();
                t = IntTensor { data, shape: (n, 1, 1, dp.dout), exp: oe };
                intpath::relu_int(&mut t);
            }
            None => {
                assert_eq!(i + 1, names.len(), "{name}: logits mid-stack");
                let s = (dp.acc_exp as f32).exp2();
                return Tensor::new(
                    (n, 1, 1, dp.dout),
                    acc.iter().map(|&a| a as f32 * s).collect());
            }
        }
    }
    unreachable!("dense stack without a logits layer");
}

/// The pre-graph `PlanRunner::forward` LeNet-5 arm, verbatim (with the
/// hand-coded integer head above in place of the old f32 head).
fn legacy_plan_forward_lenet(plan: &QuantPlan, strategy: KernelStrategy,
                             x: &Tensor) -> Tensor {
    let q = intpath::quantize_input(x, plan.input_exp, plan.cfg.bits);
    let mut y = legacy_plan_conv_block(plan, strategy, "conv1", &q);
    intpath::relu_int(&mut y);
    let y = intpath::avg_pool2_int(&y);
    let mut y = legacy_plan_conv_block(plan, strategy, "conv2", &y);
    intpath::relu_int(&mut y);
    let y = intpath::avg_pool2_int(&y);
    let (n, h, w, c) = y.shape;
    let y = IntTensor { data: y.data, shape: (n, 1, 1, h * w * c), exp: y.exp };
    legacy_head(plan, strategy, &y, &["fc1", "fc2", "fc3"])
}

/// The pre-graph `PlanRunner::forward` ResNet arm, verbatim, driven by a
/// literal block table.
fn legacy_plan_forward_resnet(plan: &QuantPlan, strategy: KernelStrategy,
                              x: &Tensor, blocks: &[(&str, bool)]) -> Tensor {
    let reg_max = plan.qmax() << intpath::HEADROOM_BITS;
    let q = intpath::quantize_input(x, plan.input_exp, plan.cfg.bits);
    let mut y = legacy_plan_conv_block(plan, strategy, "stem", &q);
    intpath::relu_int(&mut y);
    for &(pre, has_sc) in blocks {
        let mut h = legacy_plan_conv_block(plan, strategy,
                                           &format!("{pre}/c1"), &y);
        intpath::relu_int(&mut h);
        let mut h = legacy_plan_conv_block(plan, strategy,
                                           &format!("{pre}/c2"), &h);
        let sc = if has_sc {
            legacy_plan_conv_block(plan, strategy, &format!("{pre}/sc"), &y)
        } else {
            intpath::shift_to(&y, h.exp, reg_max)
        };
        assert_eq!(h.exp, sc.exp, "{pre}: residual grids diverge");
        for (v, &s2) in h.data.iter_mut().zip(&sc.data) {
            *v = (*v + s2).clamp(-reg_max, reg_max);
        }
        intpath::relu_int(&mut h);
        y = h;
    }
    let y = intpath::global_avg_pool_int(&y);
    legacy_head(plan, strategy, &y, &["fc"])
}

/// The graph-driven `PlanRunner` must reproduce the legacy hand-coded
/// integer walk BIT-IDENTICALLY (the conv stack is i32-exact, the dense
/// head is integer to the logits, and the final rescale is one exact
/// pow2 move) for every pre-existing architecture, every kernel
/// strategy and both serving widths.
#[test]
fn graph_walk_bit_identical_to_legacy_int_walk() {
    let mut rng = XorShift64::new(4321);
    let x = rand_tensor(&mut rng, (1, 32, 32, 1), 1.0);
    for (arch, blocks, widths) in [
        (Arch::Lenet5, None, &[8u32, 16][..]),
        (Arch::Resnet8, Some(RESNET8_BLOCKS), &[8][..]),
        (Arch::Resnet20, Some(RESNET20_BLOCKS), &[8][..]),
    ] {
        let params = synth_params(arch, 42);
        let calib: Calibration = params.keys()
            .filter_map(|k| k.strip_suffix("/conv_w"))
            .map(|n| (n.to_string(),
                      LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
            .collect();
        for &bits in widths {
            let cfg = QuantCfg { bits, mode: Mode::SharedScale };
            let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg,
                                        &calib).unwrap();
            for strat in STRATEGIES {
                let want = match blocks {
                    None => legacy_plan_forward_lenet(&plan, strat, &x),
                    Some(b) => legacy_plan_forward_resnet(&plan, strat, &x, b),
                };
                let got = PlanRunner { plan: &plan, strategy: strat }
                    .forward(&x);
                assert_eq!(got.shape, want.shape,
                           "{arch:?} int{bits} [{}]", strat.label());
                assert_eq!(got.data, want.data,
                           "{arch:?} int{bits} [{}]: graph-walk logits must \
                            be bit-identical to the legacy walk",
                           strat.label());
            }
        }
    }
}

/// The new graph-described architectures run the SAME plan pipeline
/// with zero executor edits: cross-strategy bit-identity holds for them
/// exactly as for the hand-coded-era networks.
#[test]
fn new_graph_archs_plan_identical_across_strategies() {
    for arch in [Arch::Cnv6, Arch::Resnet32] {
        let params = synth_params(arch, 13);
        let calib: Calibration = params.keys()
            .filter_map(|k| k.strip_suffix("/conv_w"))
            .map(|n| (n.to_string(),
                      LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
            .collect();
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg,
                                    &calib).unwrap();
        let mut rng = XorShift64::new(31);
        let x = rand_tensor(&mut rng, (1, 32, 32, 1), 1.0);
        let mut logits = Vec::new();
        for strat in STRATEGIES {
            let y = PlanRunner { plan: &plan, strategy: strat }.forward(&x);
            assert_eq!(y.shape, (1, 1, 1, 10), "{arch:?} [{}]", strat.label());
            assert!(y.data.iter().all(|v| v.is_finite()));
            logits.push(y.data);
        }
        for (i, l) in logits.iter().enumerate().skip(1) {
            assert_eq!(l, &logits[0], "{arch:?} [{}]", STRATEGIES[i].label());
        }
    }
}

/// Calibration JSON written by `repro calibrate` compiles to the same
/// plan as the in-memory table (the calibrate -> serve file round
/// trip).
#[test]
fn calibration_json_round_trip_builds_identical_plan() {
    use addernet::quant::plan::{calibration_from_json, calibration_to_json};

    let params = synth_params(Arch::Lenet5, 42);
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, 16);
    let json = calibration_to_json(&calib);
    let back: Calibration = calibration_from_json(&json).unwrap();
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let a = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, cfg, &calib)
        .unwrap();
    let b = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, cfg, &back)
        .unwrap();
    assert_eq!(a.input_exp, b.input_exp);
    for (name, cp) in &a.convs {
        let cpb = &b.convs[name];
        assert_eq!(cp.wq, cpb.wq, "{name}: weights");
        assert_eq!((cp.in_exp, cp.acc_exp, cp.out_exp),
                   (cpb.in_exp, cpb.acc_exp, cpb.out_exp), "{name}: grids");
        assert_eq!(cp.bn.mul, cpb.bn.mul, "{name}: bn mul");
        assert_eq!(cp.bn.add, cpb.bn.add, "{name}: bn add");
    }
    // the integer dense head (grids, quantized weights, folded bias)
    // must survive the calibration round trip too
    assert_eq!(a.dense, b.dense);
}
