//! Oracle tests for the plan-based integer pipeline
//! (`quant::plan::QuantPlan` + `sim::intpath::PlanRunner`):
//!
//! * **first-layer bit-identity** — on the first conv layer the plan
//!   path (weights quantized at build time, input quantized once) must
//!   reproduce the per-call `conv2d_quant` reference EXACTLY, for every
//!   `KernelStrategy`, both kernels and both serving widths: the same
//!   shared exponent (§3.1) drives both paths, so the integer operands
//!   — and therefore the i32 accumulators — are the same integers;
//! * **cross-strategy whole-model identity** — the int stack is
//!   i32-exact, so full forward passes agree across
//!   Naive/Tiled/Simd/Auto bit for bit through the conv chain (and to
//!   f32 round-off through the shared dense head);
//! * **plan vs per-call tracking** — the compiled plan serves logits
//!   close to the per-call experiment path and the f32 reference at
//!   int16/int8.

use addernet::quant::plan::QuantPlan;
use addernet::quant::{Calibration, Mode};
use addernet::report::quantrep;
use addernet::sim::functional::{self, conv2d_quant_with, synth_params, Arch,
                                ConvW, ExecMode, KernelStrategy, QConvW,
                                QuantCfg, Runner, SimKernel, Tensor};
use addernet::sim::intpath::{self, PlanRunner};
use addernet::util::XorShift64;

const STRATEGIES: [KernelStrategy; 4] = [
    KernelStrategy::Naive,
    KernelStrategy::Tiled,
    KernelStrategy::Simd,
    KernelStrategy::Auto,
];

fn rand_tensor(rng: &mut XorShift64, shape: (usize, usize, usize, usize),
               scale: f32) -> Tensor {
    let (n, h, w, c) = shape;
    Tensor::new(shape, (0..n * h * w * c).map(|_| rng.next_f32_sym(scale)).collect())
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol, "{what}: element {i}: {x} vs {y} (tol {tol})");
    }
}

fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0f32, |m, &v| m.max(v.abs()))
}

/// The plan's first conv layer, executed on the raw integer engine,
/// must be bit-identical to the per-call `conv2d_quant` reference:
/// identical operands on the shared grid, identical i32 accumulators,
/// identical dequantization scale.
#[test]
fn first_layer_bit_identical_to_percall_reference() {
    let params = synth_params(Arch::Lenet5, 42);
    let mut rng = XorShift64::new(11);
    let x = rand_tensor(&mut rng, (2, 32, 32, 1), 1.0);
    for kind in [SimKernel::Adder, SimKernel::Mult] {
        let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, kind, 16);
        // int16 only for the adder kernel: its accumulator is provably
        // i32-bounded (|acc| <= 2*qmax*K), while int16 MULT products
        // can overflow the widened accumulator on large layers.
        let widths: &[u32] = match kind {
            SimKernel::Adder => &[8, 16],
            SimKernel::Mult => &[8],
        };
        for &bits in widths {
            let cfg = QuantCfg { bits, mode: Mode::SharedScale };
            let plan = QuantPlan::build(&params, Arch::Lenet5, kind, cfg, &calib)
                .unwrap();
            let lp = &plan.convs["conv1"];
            assert_eq!(plan.input_exp, lp.in_exp);
            let (ws, wd) = &params["conv1/conv_w"];
            let cw = ConvW { data: wd, kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3] };
            let lc = &calib["conv1"];
            let scale = (lp.acc_exp as f32).exp2();
            for strat in STRATEGIES {
                let want = conv2d_quant_with(strat, &x, &cw, lp.stride,
                                             lp.padding, kind, cfg, lc);
                let xq = intpath::quantize_input(&x, plan.input_exp, bits);
                let qw = QConvW { data: &lp.wq, kh: lp.kh, kw: lp.kw,
                                  cin: lp.cin, cout: lp.cout };
                let (acc, oshape) = functional::conv2d_int_with(
                    strat, &xq.data, xq.shape, &qw, lp.stride, lp.padding, kind);
                assert_eq!(oshape, want.shape,
                           "{kind:?} int{bits} [{}]", strat.label());
                for (i, (&a, &w)) in acc.iter().zip(&want.data).enumerate() {
                    let got = a as f32 * scale;
                    assert!(got == w,
                            "{kind:?} int{bits} [{}] element {i}: plan {got} \
                             vs per-call {w}", strat.label());
                }
            }
        }
    }
}

/// Whole-model plan execution is bit-identical across every kernel
/// strategy: the conv stack is integer-exact and the f32 head
/// accumulates in the same (ascending) order everywhere.
#[test]
fn whole_model_plan_identical_across_strategies() {
    for (arch, seed) in [(Arch::Lenet5, 3u64), (Arch::Resnet8, 5)] {
        let params = synth_params(arch, seed);
        let (calib, _) = quantrep::calibrate(&params, arch, SimKernel::Adder, 16);
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg, &calib)
            .unwrap();
        let mut rng = XorShift64::new(21);
        let x = rand_tensor(&mut rng, (2, 32, 32, 1), 1.0);
        let mut logits = Vec::new();
        for strat in STRATEGIES {
            let r = PlanRunner { plan: &plan, strategy: strat };
            let y = r.forward(&x);
            assert_eq!(y.shape, (2, 1, 1, 10), "{arch:?} [{}]", strat.label());
            assert!(y.data.iter().all(|v| v.is_finite()));
            logits.push(y.data);
        }
        for (i, l) in logits.iter().enumerate().skip(1) {
            assert_close(l, &logits[0], 1e-5,
                         &format!("{arch:?} logits [{}] vs [{}]",
                                  STRATEGIES[i].label(), STRATEGIES[0].label()));
        }
    }
}

/// Non-trivial LeNet parameters: BN scale/shift chosen so the
/// always-negative adder responses re-center into the ReLU pass-band at
/// BOTH conv layers — real signal flows through the whole int stack
/// instead of the all-zero activations identity-BN synth weights give.
fn lively_lenet_params() -> functional::Params {
    let mut params = synth_params(Arch::Lenet5, 7);
    params.insert("conv1/bn_gamma".into(), (vec![6], vec![0.1; 6]));
    params.insert("conv1/bn_beta".into(), (vec![6], vec![2.0; 6]));
    params.insert("conv2/bn_gamma".into(), (vec![16], vec![0.02; 16]));
    params.insert("conv2/bn_beta".into(), (vec![16], vec![2.5; 16]));
    params
}

/// int16 plan logits track the f32 reference closely, and int8 plan
/// logits track the per-call int8 experiment path: the compiled
/// pipeline preserves the §3.1 accuracy story end-to-end.
#[test]
fn plan_logits_track_f32_and_percall_paths() {
    let params = lively_lenet_params();
    let n = 16usize;
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, n);
    // the SAME images the calibration pass saw: ranges cover them
    let b = addernet::data::eval_set(n, 7);
    let x = Tensor::new((n, 32, 32, 1), b.images);

    let mut f32_runner = Runner {
        params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
        strategy: KernelStrategy::Auto, mode: ExecMode::F32,
        calib: None, observe: None,
    };
    let f32_logits = f32_runner.forward(&x);
    let scale = max_abs(&f32_logits.data).max(1.0);

    // int16: the plan path must sit on top of the f32 reference
    let cfg16 = QuantCfg { bits: 16, mode: Mode::SharedScale };
    let plan16 = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                  cfg16, &calib).unwrap();
    let p16 = PlanRunner { plan: &plan16, strategy: KernelStrategy::Auto }
        .forward(&x);
    assert_close(&p16.data, &f32_logits.data, 0.03 * scale, "int16 plan vs f32");

    // int8: plan and per-call approximate f32 with the same grids, so
    // they must stay near each other (and sane vs f32)
    let cfg8 = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let plan8 = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                 cfg8, &calib).unwrap();
    let p8 = PlanRunner { plan: &plan8, strategy: KernelStrategy::Auto }
        .forward(&x);
    let mut percall_runner = Runner {
        params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
        strategy: KernelStrategy::Auto, mode: ExecMode::Quant(cfg8),
        calib: Some(&calib), observe: None,
    };
    let percall = percall_runner.forward(&x);
    assert_close(&p8.data, &percall.data, 0.5 * scale, "int8 plan vs per-call");
    assert_close(&p8.data, &f32_logits.data, 0.75 * scale, "int8 plan vs f32");
}

/// Accuracy through the two quantized paths stays comparable — the
/// `quantplan` report's claim, pinned as a test.
#[test]
fn plan_accuracy_tracks_percall_accuracy() {
    let params = lively_lenet_params();
    let n = 64usize;
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, n);
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let percall = quantrep::quant_accuracy(&params, Arch::Lenet5,
                                           SimKernel::Adder, &calib, cfg, n);
    let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, cfg,
                                &calib).unwrap();
    let b = addernet::data::eval_set(n, 7);
    let x = Tensor::new((n, 32, 32, 1), b.images);
    let pacc = intpath::plan_accuracy(&plan, KernelStrategy::Auto, &x, &b.labels);
    assert!((0.0..=1.0).contains(&pacc));
    assert!((pacc - percall).abs() <= 0.3,
            "plan acc {pacc} drifted from per-call acc {percall}");
}

/// SeparateScale plans also execute (the S7 contrast mode): sane,
/// finite, cross-strategy identical.
#[test]
fn separate_scale_plan_executes() {
    let params = lively_lenet_params();
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, 8);
    let cfg = QuantCfg { bits: 8, mode: Mode::SeparateScale };
    let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, cfg,
                                &calib).unwrap();
    let mut rng = XorShift64::new(33);
    let x = rand_tensor(&mut rng, (1, 32, 32, 1), 1.0);
    let mut logits = Vec::new();
    for strat in STRATEGIES {
        let y = PlanRunner { plan: &plan, strategy: strat }.forward(&x);
        assert!(y.data.iter().all(|v| v.is_finite()));
        logits.push(y.data);
    }
    for l in logits.iter().skip(1) {
        assert_close(l, &logits[0], 1e-5, "separate-scale cross-strategy");
    }
}

/// Calibration JSON written by `repro calibrate` compiles to the same
/// plan as the in-memory table (the calibrate -> serve file round
/// trip).
#[test]
fn calibration_json_round_trip_builds_identical_plan() {
    use addernet::quant::plan::{calibration_from_json, calibration_to_json};

    let params = synth_params(Arch::Lenet5, 42);
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, 16);
    let json = calibration_to_json(&calib);
    let back: Calibration = calibration_from_json(&json).unwrap();
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let a = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, cfg, &calib)
        .unwrap();
    let b = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, cfg, &back)
        .unwrap();
    assert_eq!(a.input_exp, b.input_exp);
    for (name, cp) in &a.convs {
        let cpb = &b.convs[name];
        assert_eq!(cp.wq, cpb.wq, "{name}: weights");
        assert_eq!((cp.in_exp, cp.acc_exp, cp.out_exp),
                   (cpb.in_exp, cpb.acc_exp, cpb.out_exp), "{name}: grids");
        assert_eq!(cp.bn.mul, cpb.bn.mul, "{name}: bn mul");
        assert_eq!(cp.bn.add, cpb.bn.add, "{name}: bn add");
    }
}
