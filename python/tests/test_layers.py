"""L2 layer tests: AdderNet surrogate gradients, STE projections, BN."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers
from compile.kernels import ref


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


class TestAdderGradients:
    """The AdderNet training rules (Chen et al. CVPR'20):
    dW gets the full-precision (F - W) gradient, dX gets HardTanh."""

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 40), k=st.integers(1, 20), n=st.integers(1, 10),
           seed=st.integers(0, 2**16))
    def test_weight_grad_full_precision(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a, b = _rand(rng, (m, k)), _rand(rng, (k, n))
        g = _rand(rng, (m, n))
        _, vjp = jax.vjp(layers.l1_gemm_train, a, b)
        _, db = vjp(g)
        # manual: dB[k,n] = sum_m g[m,n] * (a[m,k] - b[k,n])
        want = np.einsum("mn,mk->kn", np.asarray(g), np.asarray(a)) \
            - np.asarray(b) * np.asarray(g).sum(0)[None, :]
        np.testing.assert_allclose(np.asarray(db), want, rtol=1e-4,
                                   atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 40), k=st.integers(1, 20), n=st.integers(1, 10),
           seed=st.integers(0, 2**16))
    def test_input_grad_hardtanh(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a, b = _rand(rng, (m, k), 2.0), _rand(rng, (k, n), 2.0)
        g = _rand(rng, (m, n))
        _, vjp = jax.vjp(layers.l1_gemm_train, a, b)
        da, _ = vjp(g)
        want = np.einsum(
            "mn,mkn->mk", np.asarray(g),
            np.clip(np.asarray(b)[None, :, :] - np.asarray(a)[:, :, None],
                    -1.0, 1.0))
        np.testing.assert_allclose(np.asarray(da), want, rtol=1e-4,
                                   atol=1e-4)

    def test_forward_value_matches_ref(self):
        rng = np.random.default_rng(0)
        a, b = _rand(rng, (33, 17)), _rand(rng, (17, 9))
        np.testing.assert_allclose(
            np.asarray(layers.l1_gemm_train(a, b)),
            np.asarray(ref.l1_gemm_ref(a, b)), rtol=1e-5, atol=1e-4)

    def test_chunked_ref_matches_dense(self):
        rng = np.random.default_rng(1)
        a, b = _rand(rng, (2050, 13)), _rand(rng, (13, 7))
        np.testing.assert_allclose(
            np.asarray(layers._l1_gemm_chunked(a, b, cm=512)),
            np.asarray(ref.l1_gemm_ref(a, b)), rtol=1e-5, atol=1e-4)

    def test_input_grad_is_bounded(self):
        """HardTanh clip => |dX| <= sum_n |g| regardless of magnitudes."""
        rng = np.random.default_rng(2)
        a, b = _rand(rng, (10, 5), 100.0), _rand(rng, (5, 4), 100.0)
        g = jnp.ones((10, 4))
        _, vjp = jax.vjp(layers.l1_gemm_train, a, b)
        da, _ = vjp(g)
        assert np.all(np.abs(np.asarray(da)) <= 4.0 + 1e-6)

    def test_conv_grads_flow(self):
        rng = np.random.default_rng(3)
        x = _rand(rng, (2, 8, 8, 3))
        w = _rand(rng, (3, 3, 3, 4))

        def loss(x, w):
            return jnp.sum(layers.adder_conv2d(x, w) ** 2)

        gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
        assert gx.shape == x.shape and gw.shape == w.shape
        assert float(jnp.max(jnp.abs(gw))) > 0.0
        assert float(jnp.max(jnp.abs(gx))) > 0.0


class TestShiftXnor:
    def test_shift_weights_are_pow2(self):
        rng = np.random.default_rng(0)
        w = _rand(rng, (3, 3, 2, 4))
        ws = np.abs(np.asarray(layers.shift_quantize_weights(w)))
        exps = np.log2(ws)
        np.testing.assert_allclose(exps, np.round(exps), atol=1e-6)
        assert ws.max() <= 1.0 and ws.min() >= 2.0 ** -8

    def test_shift_ste_passes_gradient(self):
        w = jnp.asarray(np.linspace(-2, 2, 24).astype(np.float32)
                        ).reshape(1, 1, 4, 6)
        g = jax.grad(lambda w: jnp.sum(layers.shift_quantize_weights(w)))(w)
        assert float(jnp.max(jnp.abs(g))) > 0.0

    def test_xnor_weights_are_binary_scaled(self):
        rng = np.random.default_rng(1)
        w = _rand(rng, (3, 3, 2, 4))
        wb = np.asarray(layers.xnor_binarize_weights(w))
        for co in range(4):
            vals = np.unique(np.abs(wb[..., co]))
            assert len(vals) == 1  # single alpha per filter
            alpha = np.mean(np.abs(np.asarray(w)[..., co]))
            np.testing.assert_allclose(vals[0], alpha, rtol=1e-5)


class TestBatchNormPooling:
    def test_bn_train_normalizes(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, (8, 4, 4, 3), 5.0) + 7.0
        g = jnp.ones((3,))
        b = jnp.zeros((3,))
        y, m, v = layers.batch_norm_train(x, g, b, jnp.zeros(3), jnp.ones(3))
        np.testing.assert_allclose(np.asarray(jnp.mean(y, (0, 1, 2))),
                                   np.zeros(3), atol=1e-4)
        np.testing.assert_allclose(np.asarray(jnp.std(y, (0, 1, 2))),
                                   np.ones(3), atol=1e-3)

    def test_bn_running_stats_update(self):
        x = jnp.ones((4, 2, 2, 1)) * 10.0
        _, m, v = layers.batch_norm_train(
            x, jnp.ones(1), jnp.zeros(1), jnp.zeros(1), jnp.ones(1),
            momentum=0.9)
        np.testing.assert_allclose(float(m[0]), 1.0, atol=1e-6)  # 0.9*0+0.1*10
        np.testing.assert_allclose(float(v[0]), 0.9, atol=1e-6)  # 0.9*1+0.1*0

    def test_bn_eval_uses_running_stats(self):
        x = jnp.ones((2, 2, 2, 1)) * 3.0
        y = layers.batch_norm_eval(x, jnp.ones(1), jnp.zeros(1),
                                   jnp.asarray([1.0]), jnp.asarray([4.0]))
        np.testing.assert_allclose(np.asarray(y), (3 - 1) / np.sqrt(4 + 1e-5),
                                   rtol=1e-4)

    def test_avg_pool(self):
        x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
        y = layers.avg_pool(x, 2)
        np.testing.assert_allclose(
            np.asarray(y[0, :, :, 0]),
            np.array([[2.5, 4.5], [10.5, 12.5]]))

    def test_max_pool(self):
        x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
        y = layers.max_pool(x, 2)
        np.testing.assert_allclose(
            np.asarray(y[0, :, :, 0]), np.array([[5.0, 7.0], [13.0, 15.0]]))


def test_impl_toggle_equivalence():
    """pallas vs ref forward impl must agree (the aot --impl contract)."""
    rng = np.random.default_rng(5)
    a, b = _rand(rng, (50, 30)), _rand(rng, (30, 12))
    layers.set_impl("pallas")
    y1 = layers.l1_gemm_train(a, b)
    layers.set_impl("ref")
    y2 = layers.l1_gemm_train(a, b)
    layers.set_impl("pallas")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-4)
