"""Synthetic dataset generator: determinism + the cross-language goldens
that the Rust mirror (rust/src/data) is pinned against."""

import hashlib

import numpy as np

from compile import data

# Golden pixel values (uint8) for seed=42 — the SAME constants appear in
# rust/src/data/tests; regenerate with python -m compile.data if the
# generator ever changes (it should not).
GOLDENS = [
    # (sample, y, x, pixel)
    (0, 0, 0, 29),
    (0, 13, 17, 30),
    (3, 5, 5, 222),
    (9, 31, 31, 35),
    (7, 16, 2, 55),
    (5, 10, 20, 27),
]
GOLDEN_SHA16 = "f82b57f89133d6d1"  # sha256 prefix of 12 images, seed=42


def _to_u8(x):
    return np.round((x + 1.0) * 127.5).astype(np.uint8)


def test_golden_pixels():
    x, _ = data.generate(12, seed=42)
    u8 = _to_u8(x)
    for s, yy, xx, want in GOLDENS:
        assert int(u8[s, yy, xx, 0]) == want, (s, yy, xx)


def test_golden_hash():
    x, _ = data.generate(12, seed=42)
    h = hashlib.sha256(_to_u8(x).tobytes()).hexdigest()[:16]
    assert h == GOLDEN_SHA16


def test_determinism_and_offset_consistency():
    a, ya = data.generate(20, seed=5, offset=0)
    b, yb = data.generate(8, seed=5, offset=12)
    np.testing.assert_array_equal(a[12:], b)
    np.testing.assert_array_equal(ya[12:], yb)


def test_labels_cycle_classes():
    _, y = data.generate(25, seed=0, offset=3)
    np.testing.assert_array_equal(y, (np.arange(3, 28) % 10))


def test_value_range_and_dtype():
    x, y = data.generate(30, seed=1)
    assert x.dtype == np.float32 and y.dtype == np.int32
    assert x.shape == (30, 32, 32, 1)
    assert x.min() >= -1.0 and x.max() <= 1.0


def test_classes_are_distinguishable():
    """Mean intra-class L2 distance should be smaller than inter-class —
    otherwise the dataset carries no signal to learn."""
    x, y = data.generate(200, seed=9)
    flat = x.reshape(200, -1)
    intra, inter = [], []
    for c in range(10):
        xc = flat[y == c]
        mu = xc.mean(0)
        intra.append(np.mean(np.linalg.norm(xc - mu, axis=1)))
    mus = np.stack([flat[y == c].mean(0) for c in range(10)])
    for i in range(10):
        for j in range(i + 1, 10):
            inter.append(np.linalg.norm(mus[i] - mus[j]))
    assert np.mean(inter) > np.mean(intra) * 0.5


def test_batches_iterator():
    tot = 0
    for x, y in data.batches(70, 32, seed=3):
        assert x.shape[0] in (32, 6)
        tot += x.shape[0]
    assert tot == 70


def test_seed_changes_data():
    a, _ = data.generate(10, seed=1)
    b, _ = data.generate(10, seed=2)
    assert np.abs(a - b).max() > 0.1
