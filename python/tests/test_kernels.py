"""L1 kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes/dtypes/tile sizes of the Pallas kernels and
asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adder_conv, mult_conv, quant, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32) * 3.0
    return jnp.asarray(x, dtype=dtype)


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-4),
       jnp.bfloat16: dict(rtol=0.05, atol=0.5)}


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70), k=st.integers(1, 48), n=st.integers(1, 24),
    bm=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16]),
    bn=st.sampled_from([8, 16]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_l1_gemm_matches_ref(m, k, n, bm, bk, bn, dtype, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, k), dtype)
    b = _rand(rng, (k, n), dtype)
    got = adder_conv.l1_gemm(a, b, bm=bm, bk=bk, bn=bn)
    want = ref.l1_gemm_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **TOL[dtype])


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70), k=st.integers(1, 48), n=st.integers(1, 24),
    bm=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16]),
    bn=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_pallas_matmul_matches_ref(m, k, n, bm, bk, bn, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, k), jnp.float32)
    b = _rand(rng, (k, n), jnp.float32)
    got = mult_conv.matmul(a, b, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3), hw=st.integers(5, 14),
    cin=st.integers(1, 4), cout=st.integers(1, 6),
    ksz=st.sampled_from([1, 3, 5]), stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**16),
)
def test_adder_conv2d_matches_ref(b, hw, cin, cout, ksz, stride, padding,
                                  seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, hw, hw, cin), jnp.float32)
    w = _rand(rng, (ksz, ksz, cin, cout), jnp.float32)
    got = adder_conv.adder_conv2d(x, w, stride, padding, bm=16, bk=8, bn=8)
    want = ref.adder_conv2d_ref(x, w, stride, padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3), hw=st.integers(5, 14),
    cin=st.integers(1, 4), cout=st.integers(1, 6),
    ksz=st.sampled_from([1, 3, 5]), stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_mult_conv2d_matches_lax_conv(b, hw, cin, cout, ksz, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, hw, hw, cin), jnp.float32)
    w = _rand(rng, (ksz, ksz, cin, cout), jnp.float32)
    got = mult_conv.mult_conv2d(x, w, stride, "SAME", bm=16, bk=8, bn=8)
    want = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000), exp=st.integers(-8, 2),
    bits=st.sampled_from([4, 5, 6, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_quantize_matches_ref(n, exp, bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n,)).astype(np.float32) * 4.0)
    got = quant.quantize(x, float(exp), bits, block=512)
    want = ref.quantize_ref(x, jnp.float32(exp), bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_is_integer_grid():
    x = jnp.linspace(-9.0, 9.0, 1001)
    q = quant.quantize(x, -2.0, 8)
    assert np.all(np.asarray(q) == np.round(np.asarray(q)))
    qmax = 2 ** 7 - 1
    assert np.all(np.abs(np.asarray(q)) <= qmax)


def test_shared_scale_exponent_covers_range():
    for bits in (4, 6, 8, 16):
        max_abs = jnp.float32(7.3)
        e = float(ref.shared_scale_exp(max_abs, bits))
        qmax = 2 ** (bits - 1) - 1
        assert qmax * 2.0 ** e >= 7.3
        # one exponent lower must NOT cover
        assert qmax * 2.0 ** (e - 1) < 7.3


def test_shared_scale_factors_out_of_l1():
    """-|a-b| is 1-homogeneous: the shared scale factors out exactly —
    the paper's no-point-alignment argument (§3.1)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 2)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((3, 3, 2, 4)).astype(np.float32))
    e = float(ref.shared_scale_exp(
        jnp.maximum(jnp.max(jnp.abs(x)), jnp.max(jnp.abs(w))), 8))
    xq = ref.quantize_ref(x, jnp.float32(e), 8)
    wq = ref.quantize_ref(w, jnp.float32(e), 8)
    # integer conv then dequant == conv of dequantized tensors
    lhs = ref.adder_conv2d_ref(xq, wq) * 2.0 ** e
    rhs = ref.adder_conv2d_ref(xq * 2.0 ** e, wq * 2.0 ** e)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-5, atol=1e-5)


def test_l1_gemm_padding_is_neutral():
    """Padded K entries must not change the distance sum."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((5, 7)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((7, 3)).astype(np.float32))
    got = adder_conv.l1_gemm(a, b, bm=8, bk=8, bn=8)  # pads K 7->8
    want = ref.l1_gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_im2col_feature_order():
    """Patch features must be (kh, kw, C) row-major — the order the Rust
    functional simulator assumes."""
    x = jnp.arange(1 * 4 * 4 * 2, dtype=jnp.float32).reshape(1, 4, 4, 2)
    p = ref.im2col(x, 2, 2, 1, "VALID")
    # patch at (0,0): pixels (0,0),(0,1),(1,0),(1,1), channels innermost
    expect = jnp.stack([x[0, 0, 0, 0], x[0, 0, 0, 1],
                        x[0, 0, 1, 0], x[0, 0, 1, 1],
                        x[0, 1, 0, 0], x[0, 1, 0, 1],
                        x[0, 1, 1, 0], x[0, 1, 1, 1]])
    np.testing.assert_array_equal(np.asarray(p[0, 0, 0]), np.asarray(expect))
