"""AOT artifact tests: manifest consistency and HLO-text validity.

These run against the artifacts/ directory if `make artifacts` has been
run; a fast lowering smoke test runs regardless.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ART = os.path.exists(os.path.join(ART, "manifest.json"))


def test_lowering_smoke():
    """eval graph lowers to parseable HLO text without artifacts."""
    p = model.init_params("lenet5")
    x = jax.ShapeDtypeStruct((4, 32, 32, 1), jnp.float32)
    lowered = jax.jit(model.make_eval_step("lenet5", "mult")).lower(
        aot._abstract(p), x)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_train_step_lowering_has_tuple_output():
    p = model.init_params("lenet5")
    m = model.init_momenta(p)
    x = jax.ShapeDtypeStruct((2, 32, 32, 1), jnp.float32)
    y = jax.ShapeDtypeStruct((2,), jnp.int32)
    s = jax.ShapeDtypeStruct((), jnp.int32)
    fn = model.make_train_step("lenet5", "mult")
    lowered = jax.jit(fn).lower(aot._abstract(p), aot._abstract(m), x, y, s)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # one output leaf per param + momentum + loss + acc
    n_out = len(jax.tree_util.tree_leaves(lowered.out_info))
    assert n_out == len(p) + len(m) + 2


@pytest.mark.skipif(not HAVE_ART, reason="run `make artifacts` first")
class TestArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_all_graph_files_exist(self, manifest):
        for name, g in manifest["graphs"].items():
            path = os.path.join(ART, g["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), name

    def test_init_bins_match_layout(self, manifest):
        for arch, info in manifest["params"].items():
            size = os.path.getsize(os.path.join(ART, info["init_file"]))
            total = sum(e["size"] for e in info["layout"])
            assert size == total * 4, arch
            # layout is sorted by name and offsets are contiguous
            names = [e["name"] for e in info["layout"]]
            assert names == sorted(names)
            off = 0
            for e in info["layout"]:
                assert e["offset"] == off
                off += e["size"]

    def test_layout_matches_model_init(self, manifest):
        for arch, info in manifest["params"].items():
            p = model.init_params(arch)
            assert [e["name"] for e in info["layout"]] == sorted(p.keys())
            for e in info["layout"]:
                assert list(p[e["name"]].shape) == e["shape"]

    def test_train_graph_io_orders(self, manifest):
        for name, g in manifest["graphs"].items():
            if g["kind"] != "train":
                continue
            n_in = len(g["input_order"])
            assert n_in == g["n_params"] + g["n_momenta"] + 3
            assert g["output_order"][-2:] == ["loss", "acc"]
            # state feedback contract: output i is input i for all state
            n_state = g["n_params"] + g["n_momenta"]
            assert g["input_order"][:n_state] == g["output_order"][:n_state]

    def test_trainable_subset(self, manifest):
        for arch, info in manifest["params"].items():
            tr = set(info["trainable"])
            assert all(model.is_trainable(n) for n in tr)
            all_names = {e["name"] for e in info["layout"]}
            assert tr <= all_names
