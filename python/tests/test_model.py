"""L2 model tests: shapes, training dynamics, optimizer rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, layers, model

layers.set_impl("ref")  # fast path for model-level tests; equivalence is
# pinned by test_layers.test_impl_toggle_equivalence


@pytest.fixture(scope="module")
def batch():
    x, y = data.generate(16, seed=7)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("arch", model.ARCHS)
@pytest.mark.parametrize("kernel", model.KERNELS)
def test_forward_shapes(arch, kernel, batch):
    if arch == "resnet20" and kernel != "adder":
        pytest.skip("resnet20 covered for adder only (runtime)")
    x, _ = batch
    p = model.init_params(arch)
    logits, ns = model.forward(p, x, arch, kernel, train=True)
    assert logits.shape == (16, 10)
    assert all(k.endswith(("/bn_mean", "/bn_var")) for k in ns)
    logits_e, ns_e = model.forward(p, x, arch, kernel, train=False)
    assert logits_e.shape == (16, 10) and not ns_e


@pytest.mark.parametrize("kernel", ["adder", "mult"])
def test_lenet_loss_decreases(kernel, batch):
    x, y = batch
    p = model.init_params("lenet5")
    m = model.init_momenta(p)
    step_fn = jax.jit(model.make_train_step("lenet5", kernel, base_lr=0.05,
                                            total_steps=30))
    losses = []
    for i in range(12):
        p, m, loss, acc = step_fn(p, m, x, y, jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_adder_resnet_loss_decreases(batch):
    x, y = batch
    p = model.init_params("resnet8")
    m = model.init_momenta(p)
    step_fn = jax.jit(model.make_train_step("resnet8", "adder",
                                            base_lr=0.05, total_steps=30))
    l0 = lN = None
    for i in range(8):
        p, m, loss, _ = step_fn(p, m, x, y, jnp.int32(i))
        l0 = l0 if l0 is not None else float(loss)
        lN = float(loss)
    assert lN < l0


def test_bn_state_updates_during_training(batch):
    x, y = batch
    p = model.init_params("lenet5")
    m = model.init_momenta(p)
    step_fn = jax.jit(model.make_train_step("lenet5", "adder"))
    p2, _, _, _ = step_fn(p, m, x, y, jnp.int32(0))
    assert float(jnp.max(jnp.abs(p2["conv1/bn_mean"]
                                 - p["conv1/bn_mean"]))) > 0.0


def test_momenta_only_trainable():
    p = model.init_params("lenet5")
    m = model.init_momenta(p)
    assert all(model.is_trainable(k) for k in m)
    assert len(m) == sum(model.is_trainable(k) for k in p)


def test_cosine_lr_schedule():
    lr0 = float(model.cosine_lr(jnp.int32(0), 0.1, 100))
    lr_half = float(model.cosine_lr(jnp.int32(50), 0.1, 100))
    lr_end = float(model.cosine_lr(jnp.int32(100), 0.1, 100))
    assert abs(lr0 - 0.1) < 1e-6
    assert abs(lr_half - 0.05) < 1e-6
    assert lr_end < 1e-6


def test_adaptive_lr_scales_adder_updates(batch):
    """Adder conv weights must receive the sqrt(k)/||g|| scaled step:
    after one step from zero momentum, ||delta W|| == lr * sqrt(k) (+wd)."""
    x, y = batch
    p = model.init_params("lenet5")
    m = model.init_momenta(p)
    lr, total = 0.01, 1000
    step_fn = jax.jit(model.make_train_step(
        "lenet5", "adder", base_lr=lr, total_steps=total, momentum=0.0,
        weight_decay=0.0))
    p2, _, _, _ = step_fn(p, m, x, y, jnp.int32(0))
    dw = np.asarray(p2["conv1/conv_w"] - p["conv1/conv_w"])
    k = dw.size
    np.testing.assert_allclose(np.linalg.norm(dw), lr * np.sqrt(k),
                               rtol=1e-3)


def test_probe_layer_names_match_probe_outputs(batch):
    x, _ = batch
    for arch in ("lenet5", "resnet8"):
        p = model.init_params(arch)
        probe = model.make_probe(arch, "adder")
        feats = probe(p, x)
        # one flattened feature tensor per conv layer + the logits
        assert len(feats) == len(model.probe_layer_names(arch)) + 1
        assert all(f.ndim == 1 for f in feats[:-1])
        assert feats[-1].shape == (x.shape[0], 10)


def test_cross_entropy_known_value():
    logits = jnp.asarray([[10.0, 0.0, 0.0]])
    y = jnp.asarray([0])
    assert float(model.cross_entropy(logits, y)) < 1e-3
    y_wrong = jnp.asarray([1])
    assert float(model.cross_entropy(logits, y_wrong)) > 5.0


def test_accuracy_metric():
    logits = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 0.0]])
    y = jnp.asarray([0, 1, 1, 0])
    assert abs(float(model.accuracy(logits, y)) - 0.75) < 1e-6
