"""Layer-2: model definitions, loss, optimizer and the exported step fns.

Architectures (32x32x1 inputs, 10 classes — the synthetic-10 dataset that
substitutes CIFAR/ImageNet, see DESIGN.md §2):

  * ``lenet5``   — the paper's Fig. 5 on-chip workload (2 conv + 3 fc).
  * ``resnet8``  — 1 residual block per stage (fast CI-scale ResNet).
  * ``resnet20`` — 3 blocks per stage (the paper's Fig. 2/3/7 class).

Every conv uses a selectable similarity kernel (adder / mult / shift /
xnor, see layers.py); dense heads stay multiply-based, mirroring common
practice (the paper replaces *convolutions*).

The exported graphs (lowered to HLO text by aot.py and executed by the
Rust coordinator, which owns all state) are:

  * ``train_step(params, momenta, x, y, step)``
        -> (new_params, new_momenta, loss, acc)
    One fused fwd+bwd+SGD(momentum, cosine LR, weight decay) step with the
    AdderNet adaptive local learning rate on adder conv weights.
  * ``eval_step(params, x) -> logits``           (BN in inference mode)
  * ``probe(params, x) -> per-adder-layer feature tensors``  (Fig. 3a/b)
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers

Params = Dict[str, jnp.ndarray]

# Names with these suffixes are running statistics, not SGD-trainable.
_STATE_SUFFIXES = ("/bn_mean", "/bn_var")

ARCHS = ("lenet5", "resnet8", "resnet20")
KERNELS = ("adder", "mult", "shift", "xnor")


def is_trainable(name: str) -> bool:
    return not name.endswith(_STATE_SUFFIXES)


def is_adder_conv_w(name: str, kernel: str) -> bool:
    return kernel == "adder" and name.endswith("/conv_w")


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------

def _he(rng: np.random.Generator, shape, fan_in) -> np.ndarray:
    return (rng.standard_normal(shape) * math.sqrt(2.0 / fan_in)).astype(
        np.float32)


def _conv_block_init(p, rng, name, kh, kw, cin, cout):
    p[f"{name}/conv_w"] = _he(rng, (kh, kw, cin, cout), kh * kw * cin)
    p[f"{name}/bn_gamma"] = np.ones((cout,), np.float32)
    p[f"{name}/bn_beta"] = np.zeros((cout,), np.float32)
    p[f"{name}/bn_mean"] = np.zeros((cout,), np.float32)
    p[f"{name}/bn_var"] = np.ones((cout,), np.float32)


def _dense_init(p, rng, name, din, dout):
    p[f"{name}/dense_w"] = _he(rng, (din, dout), din)
    p[f"{name}/dense_b"] = np.zeros((dout,), np.float32)


def _resnet_stages(arch: str) -> int:
    return {"resnet8": 1, "resnet20": 3}[arch]


def init_params(arch: str, seed: int = 0) -> Params:
    """Initial parameters as an insertion-ordered dict (the flattening
    order the manifest records and the Rust driver relies on)."""
    rng = np.random.default_rng(seed)
    p: Dict[str, np.ndarray] = {}
    if arch == "lenet5":
        _conv_block_init(p, rng, "conv1", 5, 5, 1, 6)
        _conv_block_init(p, rng, "conv2", 5, 5, 6, 16)
        _dense_init(p, rng, "fc1", 400, 120)
        _dense_init(p, rng, "fc2", 120, 84)
        _dense_init(p, rng, "fc3", 84, 10)
    elif arch in ("resnet8", "resnet20"):
        n = _resnet_stages(arch)
        _conv_block_init(p, rng, "stem", 3, 3, 1, 16)
        cin = 16
        for s, cout in enumerate((16, 32, 64)):
            for b in range(n):
                pre = f"s{s}b{b}"
                _conv_block_init(p, rng, f"{pre}/c1", 3, 3, cin, cout)
                _conv_block_init(p, rng, f"{pre}/c2", 3, 3, cout, cout)
                if cin != cout:
                    _conv_block_init(p, rng, f"{pre}/sc", 1, 1, cin, cout)
                cin = cout
        _dense_init(p, rng, "fc", 64, 10)
    else:
        raise ValueError(arch)
    return {k: jnp.asarray(v) for k, v in p.items()}


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _conv_bn(p, new_state, name, x, kernel, stride, padding, train,
             probe_acc=None):
    if probe_acc is not None:
        probe_acc.append((name, x))
    conv = layers.CONV_FNS[kernel]
    y = conv(x, p[f"{name}/conv_w"], stride=stride, padding=padding)
    if train:
        y, m, v = layers.batch_norm_train(
            y, p[f"{name}/bn_gamma"], p[f"{name}/bn_beta"],
            p[f"{name}/bn_mean"], p[f"{name}/bn_var"])
        new_state[f"{name}/bn_mean"] = m
        new_state[f"{name}/bn_var"] = v
    else:
        y = layers.batch_norm_eval(
            y, p[f"{name}/bn_gamma"], p[f"{name}/bn_beta"],
            p[f"{name}/bn_mean"], p[f"{name}/bn_var"])
    return y


def forward(p: Params, x: jnp.ndarray, arch: str, kernel: str,
            train: bool, probe_acc: List | None = None):
    """Returns (logits, dict of new BN state)."""
    ns: Dict[str, jnp.ndarray] = {}
    if arch == "lenet5":
        y = _conv_bn(p, ns, "conv1", x, kernel, 1, "VALID", train, probe_acc)
        y = layers.relu(y)
        y = layers.avg_pool(y, 2)
        y = _conv_bn(p, ns, "conv2", y, kernel, 1, "VALID", train, probe_acc)
        y = layers.relu(y)
        y = layers.avg_pool(y, 2)
        y = y.reshape(y.shape[0], -1)
        y = layers.relu(layers.dense(y, p["fc1/dense_w"], p["fc1/dense_b"]))
        y = layers.relu(layers.dense(y, p["fc2/dense_w"], p["fc2/dense_b"]))
        logits = layers.dense(y, p["fc3/dense_w"], p["fc3/dense_b"])
    elif arch in ("resnet8", "resnet20"):
        n = _resnet_stages(arch)
        y = _conv_bn(p, ns, "stem", x, kernel, 1, "SAME", train, probe_acc)
        y = layers.relu(y)
        cin = 16
        for s, cout in enumerate((16, 32, 64)):
            for b in range(n):
                pre = f"s{s}b{b}"
                stride = 2 if (s > 0 and b == 0) else 1
                h = _conv_bn(p, ns, f"{pre}/c1", y, kernel, stride, "SAME",
                             train, probe_acc)
                h = layers.relu(h)
                h = _conv_bn(p, ns, f"{pre}/c2", h, kernel, 1, "SAME",
                             train, probe_acc)
                if cin != cout:
                    sc = _conv_bn(p, ns, f"{pre}/sc", y, kernel, stride,
                                  "SAME", train, probe_acc)
                else:
                    sc = y
                y = layers.relu(h + sc)
                cin = cout
        y = layers.global_avg_pool(y)
        logits = layers.dense(y, p["fc/dense_w"], p["fc/dense_b"])
    else:
        raise ValueError(arch)
    return logits, ns


# ---------------------------------------------------------------------------
# Loss / metrics / optimizer
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(
        jnp.float32))


def cosine_lr(step: jnp.ndarray, base_lr: float, total_steps: int):
    """Paper §5: LR starts at base and decays with a cosine schedule."""
    t = jnp.clip(step.astype(jnp.float32) / float(total_steps), 0.0, 1.0)
    return base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def make_train_step(arch: str, kernel: str, base_lr: float = 0.1,
                    total_steps: int = 400, momentum: float = 0.9,
                    weight_decay: float = 5e-4):
    """Build the fused train-step the Rust coordinator drives.

    AdderNet adaptive local learning rate (Chen et al. CVPR'20 Eq. 12-13):
    for each adder conv weight, the update is scaled by sqrt(k)/||g||_2 so
    that every adder layer takes same-magnitude steps despite the L1
    kernel's unbounded gradient scale.
    """

    def train_step(params: Params, momenta: Params, x, y, step):
        def loss_fn(train_p):
            full = dict(params)
            full.update(train_p)
            logits, ns = forward(full, x, arch, kernel, train=True)
            return cross_entropy(logits, y), (logits, ns)

        train_p = {k: v for k, v in params.items() if is_trainable(k)}
        (loss, (logits, ns)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(train_p)
        lr = cosine_lr(step, base_lr, total_steps)
        new_params = dict(params)
        new_momenta = dict(momenta)
        for k, g in grads.items():
            if weight_decay and (k.endswith("/conv_w")
                                 or k.endswith("/dense_w")):
                g = g + weight_decay * params[k]
            if is_adder_conv_w(k, kernel):
                # adaptive local LR: eta * sqrt(k)/||g||2 * g
                norm = jnp.linalg.norm(g) + 1e-12
                g = g * (jnp.sqrt(float(g.size)) / norm)
            m = momentum * momenta[k] + g
            new_momenta[k] = m
            new_params[k] = params[k] - lr * m
        new_params.update(ns)  # BN running stats
        acc = accuracy(logits, y)
        return new_params, new_momenta, loss, acc

    return train_step


def make_eval_step(arch: str, kernel: str):
    def eval_step(params: Params, x):
        logits, _ = forward(params, x, arch, kernel, train=False)
        return logits

    return eval_step


def make_probe(arch: str, kernel: str):
    """Returns per-conv-layer input features (Fig. 3a/b distributions)
    plus the logits as the final output (which also keeps every parameter
    live so XLA does not prune the probe graph's inputs)."""

    def probe(params: Params, x):
        acc: List[Tuple[str, jnp.ndarray]] = []
        logits, _ = forward(params, x, arch, kernel, train=False,
                            probe_acc=acc)
        return tuple(t.reshape(-1) for _, t in acc) + (logits,)

    return probe


def probe_layer_names(arch: str) -> List[str]:
    """Conv layer names in probe output order (mirrors forward order)."""
    if arch == "lenet5":
        return ["conv1", "conv2"]
    n = _resnet_stages(arch)
    names = ["stem"]
    cin = 16
    for s, cout in enumerate((16, 32, 64)):
        for b in range(n):
            names += [f"s{s}b{b}/c1", f"s{s}b{b}/c2"]
            if cin != cout:
                names.append(f"s{s}b{b}/sc")
            cin = cout
    return names


def init_momenta(params: Params) -> Params:
    """Zero momentum buffers — only for SGD-trainable entries."""
    return {k: jnp.zeros_like(v) for k, v in params.items()
            if is_trainable(k)}
