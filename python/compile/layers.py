"""Layer-2 building blocks: the four trainable convolution kernels + glue.

Kernel types (paper Fig. 1):
  * ``adder`` — AdderNet, S = -|F-W|, with the AdderNet training rules from
    Chen et al. CVPR'20 (which this paper builds on): full-precision
    gradient for W, HardTanh-clipped gradient for X, and adaptive local
    learning-rate scaling (applied in the optimizer, see model.py).
  * ``mult``  — classical CNN cross-correlation baseline.
  * ``shift`` — DeepShift-style: weights rounded to sign * 2^round(log2|w|)
    with a straight-through estimator.
  * ``xnor``  — XNOR-net-style binary weights sign(w) * mean|w| with STE.

All convs are NHWC / HWIO.  The adder forward runs through the Layer-1
Pallas kernel (so it lowers into the exported HLO); its backward is a
memory-chunked jnp computation of the AdderNet surrogate gradients.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import adder_conv as _adder_kernel

# Toggled by aot.py: "pallas" routes the adder/mult forward through the
# Layer-1 Pallas kernels; "ref" uses the chunked pure-jnp path (identical
# numerics, pinned by python/tests/test_kernels.py).
_IMPL = {"value": "pallas"}


def set_impl(name: str) -> None:
    assert name in ("pallas", "ref"), name
    _IMPL["value"] = name


def _l1_gemm_fwd_impl(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    if _IMPL["value"] == "pallas":
        return _adder_kernel.l1_gemm(a, b, bm=512, bk=128, bn=128)
    return _l1_gemm_chunked(a, b)


def _pad_rows(a: jnp.ndarray, mult: int):
    pad = (-a.shape[0]) % mult
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    return a, pad


def _l1_gemm_chunked(a: jnp.ndarray, b: jnp.ndarray, cm: int = 1024):
    """Memory-bounded -L1 GEMM: scan over M chunks, never materialising
    more than (cm, K, N) at once."""
    m = a.shape[0]
    cm = min(cm, m)
    ap, _ = _pad_rows(a, cm)
    ac = ap.reshape(-1, cm, a.shape[1])

    def one(ch):
        return -jnp.sum(jnp.abs(ch[:, :, None] - b[None, :, :]), axis=1)

    out = jax.lax.map(one, ac).reshape(-1, b.shape[1])
    return out[:m]


@jax.custom_vjp
def l1_gemm_train(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Trainable -L1 GEMM with the AdderNet surrogate gradients."""
    return _l1_gemm_fwd_impl(a, b)


def _l1_gemm_train_fwd(a, b):
    return _l1_gemm_fwd_impl(a, b), (a, b)


def _l1_gemm_train_bwd(res, g):
    a, b = res  # (M, K), (K, N); g (M, N)
    # dB[k,n] = sum_m g[m,n] * (a[m,k] - b[k,n])     [full-precision grad]
    gsum = jnp.sum(g, axis=0)                      # (N,)
    db = jnp.einsum("mn,mk->kn", g, a) - b * gsum[None, :]
    # dA[m,k] = sum_n g[m,n] * clip(b[k,n] - a[m,k], -1, 1)   [HardTanh]
    m = a.shape[0]
    cm = min(1024, m)
    ap, _ = _pad_rows(a, cm)
    gp, _ = _pad_rows(g, cm)
    ac = ap.reshape(-1, cm, a.shape[1])
    gc = gp.reshape(-1, cm, g.shape[1])

    def one(args):
        ach, gch = args
        t = jnp.clip(b[None, :, :] - ach[:, :, None], -1.0, 1.0)  # (cm,K,N)
        return jnp.einsum("mkn,mn->mk", t, gch)

    da = jax.lax.map(one, (ac, gc)).reshape(-1, a.shape[1])[:m]
    return da, db


l1_gemm_train.defvjp(_l1_gemm_train_fwd, _l1_gemm_train_bwd)


def adder_conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                 padding: str = "SAME") -> jnp.ndarray:
    """Trainable AdderNet conv: im2col (autodiff handles its transpose)
    around the custom-vjp L1 GEMM."""
    kh, kw, cin, cout = w.shape
    pats = ref.im2col(x, kh, kw, stride, padding)
    b, ho, wo, k = pats.shape
    out = l1_gemm_train(pats.reshape(-1, k), w.reshape(k, cout))
    return out.reshape(b, ho, wo, cout)


def mult_conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                padding: str = "SAME") -> jnp.ndarray:
    """Classical conv baseline (XLA-native; the Pallas mult kernel is the
    inference-path variant, validated separately)."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


@jax.custom_vjp
def _round_ste(x):
    return jnp.round(x)


_round_ste.defvjp(lambda x: (jnp.round(x), None), lambda _, g: (g,))


@jax.custom_vjp
def _sign_ste(x):
    return jnp.sign(x)


_sign_ste.defvjp(lambda x: (jnp.sign(x), None), lambda _, g: (g,))


def shift_quantize_weights(w: jnp.ndarray) -> jnp.ndarray:
    """DeepShift weight projection: sign(w) * 2^round(log2 |w|) with STE.

    In hardware this multiplier degenerates to a barrel shifter + sign flip
    (paper Fig. 1c); here it trains with a straight-through estimator.
    """
    sign = _sign_ste(w)
    logw = jnp.log2(jnp.maximum(jnp.abs(w), 1e-8))
    e = jnp.clip(_round_ste(logw), -8.0, 0.0)  # shifts limited to 8 bits
    return sign * jnp.exp2(e)


def shift_conv2d(x, w, stride: int = 1, padding: str = "SAME"):
    return mult_conv2d(x, shift_quantize_weights(w), stride, padding)


def xnor_binarize_weights(w: jnp.ndarray) -> jnp.ndarray:
    """XNOR-net weight binarization: sign(w) * mean(|w|) per filter, STE."""
    alpha = jnp.mean(jnp.abs(w), axis=(0, 1, 2), keepdims=True)
    return _sign_ste(w) * alpha


def xnor_conv2d(x, w, stride: int = 1, padding: str = "SAME"):
    return mult_conv2d(x, xnor_binarize_weights(w), stride, padding)


CONV_FNS = {
    "adder": adder_conv2d,
    "mult": mult_conv2d,
    "shift": shift_conv2d,
    "xnor": xnor_conv2d,
}


# ---------------------------------------------------------------------------
# Normalization / pooling / dense
# ---------------------------------------------------------------------------

def batch_norm_train(x, gamma, beta, mean_state, var_state, momentum=0.9,
                     eps=1e-5):
    """BatchNorm over NHW; returns (y, new_mean_state, new_var_state).

    Mandatory after adder convs: their outputs are large negative L1
    distances and BN re-centres them (paper §2.2 / Chen et al.).
    """
    axes = tuple(range(x.ndim - 1))
    mu = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    y = (x - mu) / jnp.sqrt(var + eps) * gamma + beta
    new_mean = momentum * mean_state + (1.0 - momentum) * mu
    new_var = momentum * var_state + (1.0 - momentum) * var
    return y, new_mean, new_var


def batch_norm_eval(x, gamma, beta, mean_state, var_state, eps=1e-5):
    return (x - mean_state) / jnp.sqrt(var_state + eps) * gamma + beta


def avg_pool(x, window: int = 2, stride: int | None = None):
    stride = stride or window
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1),
        (1, stride, stride, 1), "VALID") / float(window * window)


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def max_pool(x, window: int = 2, stride: int | None = None):
    stride = stride or window
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID")


def dense(x, w, b):
    return jnp.matmul(x, w) + b


def relu(x):
    return jnp.maximum(x, 0.0)
