"""Synthetic 10-class 32x32 grayscale vision dataset.

This is the substitute for CIFAR-100 / ImageNet (see DESIGN.md §2): a
deterministic, integer-arithmetic procedural generator that is mirrored
bit-exactly in `rust/src/data/` so the Python build/test path and the Rust
training driver see the *same* images.  All randomness comes from a 31-bit
LCG so both languages agree without any RNG library.

Classes (idx % 10):
  0 horizontal stripes   5 filled circle
  1 vertical stripes     6 ring (annulus)
  2 diagonal stripes     7 square frame
  3 anti-diagonal        8 plus-sign cross
  4 checkerboard         9 LCG block pattern

Pixel = clip(base(class, y, x, s1, s2) + noise, 0, 255);
float value = pixel / 127.5 - 1.
"""

from __future__ import annotations

import numpy as np

LCG_A = 1103515245
LCG_C = 12345
LCG_M = 1 << 31
IMG = 32  # image side
N_CLASSES = 10


def _lcg_next(state: np.ndarray) -> np.ndarray:
    """One LCG step; `state` is uint64 but kept < 2**31."""
    return (state * LCG_A + LCG_C) % LCG_M


def _seed_for(seed: int, idx: np.ndarray) -> np.ndarray:
    """Per-image initial LCG state (matches rust data::sample_seed)."""
    return (np.uint64(seed) * 2654435761 + idx.astype(np.uint64) * 97 + 1) % LCG_M


def _base_pattern(cls: np.ndarray, s1: np.ndarray, s2: np.ndarray) -> np.ndarray:
    """Vectorized base image for a batch. cls/s1/s2 shape (N,), out (N,32,32) int32."""
    n = cls.shape[0]
    y = np.arange(IMG).reshape(1, IMG, 1).astype(np.int64)
    x = np.arange(IMG).reshape(1, 1, IMG).astype(np.int64)
    s1 = s1.reshape(n, 1, 1).astype(np.int64)
    s2 = s2.reshape(n, 1, 1).astype(np.int64)
    hi, lo = 220, 35
    out = np.full((n, IMG, IMG), lo, dtype=np.int64)

    def stripes(coord):
        p = 4 + s1 % 4
        return np.where(((coord + s2) % p) * 2 < p, hi, lo)

    pats = []
    pats.append(stripes(y))                      # 0 horizontal
    pats.append(stripes(x))                      # 1 vertical
    pats.append(stripes(x + y))                  # 2 diagonal
    pats.append(stripes(x - y + 64))             # 3 anti-diagonal
    c = 3 + s1 % 4                               # 4 checkerboard
    pats.append(np.where(((x // c) + (y // c)) % 2 == 0, hi, lo))
    # 5 filled circle / 6 ring
    dx = x - (16 + s2 % 7 - 3)
    dy = y - (16 + (s2 // 7) % 7 - 3)
    d2 = dx * dx + dy * dy
    r = 6 + s1 % 7
    pats.append(np.where(d2 <= r * r, hi, lo))   # 5
    band = 2 + s1 % 3
    pats.append(np.where(np.abs(d2 - r * r) <= band * r, hi, lo))  # 6
    m = 4 + s1 % 5                               # 7 square frame
    on_edge = (
        ((x == m) | (x == IMG - 1 - m)) & (y >= m) & (y <= IMG - 1 - m)
    ) | (((y == m) | (y == IMG - 1 - m)) & (x >= m) & (x <= IMG - 1 - m))
    frame_t = 1 + s2 % 2
    fr = np.zeros_like(out, dtype=bool)
    for t in range(3):  # thicken frame by up to frame_t extra pixels
        mm = m + t
        e = (
            ((x == mm) | (x == IMG - 1 - mm)) & (y >= mm) & (y <= IMG - 1 - mm)
        ) | (((y == mm) | (y == IMG - 1 - mm)) & (x >= mm) & (x <= IMG - 1 - mm))
        fr |= e & (t <= frame_t)
    pats.append(np.where(fr | on_edge, hi, lo))  # 7
    t = 2 + s1 % 3                               # 8 plus-sign cross
    cxx = 16 + s2 % 5 - 2
    pats.append(np.where((np.abs(x - cxx) < t) | (np.abs(y - cxx) < t), hi, lo))
    # 9 LCG 4x4 block pattern: 16 on/off cells from an LCG chain seeded by s1
    st = (s1 * 31 + 7) % LCG_M
    blocks = np.zeros((n, 4, 4), dtype=np.int64)
    for by in range(4):
        for bx in range(4):
            st = _lcg_next(st.astype(np.uint64)).astype(np.int64)
            blocks[:, by, bx] = np.where((st.reshape(n) >> 5) % 2 == 0, hi, lo)
    pats.append(blocks[:, (np.arange(IMG) // 8)][:, :, (np.arange(IMG) // 8)])

    cls_b = cls.reshape(n, 1, 1)
    for k, p in enumerate(pats):
        out = np.where(cls_b == k, p, out)
    return out


def generate(n: int, seed: int, offset: int = 0):
    """Generate `n` samples starting at index `offset`.

    Returns (images float32 (n,32,32,1) in [-1,1], labels int32 (n,)).
    """
    idx = np.arange(offset, offset + n, dtype=np.uint64)
    cls = (idx % N_CLASSES).astype(np.int64)
    state = _seed_for(seed, idx)
    state = _lcg_next(state)
    s1 = (state >> 7) % 1000
    state = _lcg_next(state)
    s2 = (state >> 7) % 1000
    base = _base_pattern(cls, s1.astype(np.int64), s2.astype(np.int64))
    # Per-pixel noise chain, row-major, continuing from the image state.
    noise = np.empty((n, IMG * IMG), dtype=np.int64)
    for i in range(IMG * IMG):
        state = _lcg_next(state)
        noise[:, i] = ((state >> 7) % 41).astype(np.int64) - 20
    img = np.clip(base + noise.reshape(n, IMG, IMG), 0, 255)
    fimg = (img.astype(np.float32) / 127.5) - 1.0
    return fimg[..., None], cls.astype(np.int32)


def batches(n_total: int, batch: int, seed: int, offset: int = 0):
    """Yield (x, y) batches covering [offset, offset+n_total)."""
    for start in range(0, n_total, batch):
        m = min(batch, n_total - start)
        yield generate(m, seed, offset + start)
