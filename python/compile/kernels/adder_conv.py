"""Layer-1 Pallas kernel: the AdderNet negative-L1-distance GEMM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA
implementation is a Pout x Pin array of 2-adder kernels feeding a widening
adder tree.  On a TPU-shaped target the same insight — "the similarity is a
cheap elementwise op followed by a reduction" — maps onto a matmul-style
tiling: BlockSpec carves (bm, bk) x (bk, bn) VMEM tiles (VMEM plays the role
of the FPGA BRAM line buffers), the broadcast abs-diff + sum plays the role
of the adder tree, and the K grid dimension time-multiplexes input channels
exactly as the paper's Pin loop does.  The MXU cannot compute |a-b|, so this
kernel is VPU-bound; perf analysis therefore uses the VMEM/VPU roofline, not
MXU FLOPs (see EXPERIMENTS.md §Perf).

`interpret=True` always: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO that the Rust runtime runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _l1_gemm_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One (bm, bk) x (bk, bn) tile step of out = -sum_k |a - b|.

    Grid is (M/bm, N/bn, K/bk).  The output BlockSpec index map is constant
    along the K axis, so the same output tile stays resident in VMEM across
    the sequential K steps and serves as the accumulator — the widened
    "adder tree" register of the paper's datapath.  We accumulate the
    positive L1 distance and negate on the final step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    # Broadcast abs-diff and reduce the K tile: the adder-tree step.
    o_ref[...] += jnp.sum(jnp.abs(a[:, :, None] - b[None, :, :]), axis=1)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = -o_ref[...]


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int, fill: float) -> jnp.ndarray:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=fill)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def l1_gemm(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128, bk: int = 128,
            bn: int = 128) -> jnp.ndarray:
    """out[m, n] = -sum_k |a[m, k] - b[k, n]| via the Pallas kernel.

    Shapes are padded up to tile multiples; padded K entries of A and B are
    both filled with 0 so |0 - 0| contributes nothing to the reduction, and
    padded M/N rows are sliced off the output.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    bk = min(bk, max(8, k))
    ap = _pad_to(a, bm, bk, 0.0)
    bp = _pad_to(b, bk, bn, 0.0)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_l1_gemm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def adder_conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                 padding: str = "SAME", **tiles) -> jnp.ndarray:
    """AdderNet conv built on the Pallas L1 GEMM (im2col outside the kernel).

    x: (B, H, W, Cin); w: (kh, kw, Cin, Cout) -> (B, Ho, Wo, Cout).
    """
    kh, kw, cin, cout = w.shape
    pats = ref.im2col(x, kh, kw, stride, padding)
    b, ho, wo, k = pats.shape
    out = l1_gemm(pats.reshape(-1, k), w.reshape(k, cout), **tiles)
    return out.reshape(b, ho, wo, cout)
