"""Layer-1 Pallas kernel: multiply-kernel (classical CNN) GEMM baseline.

Identical tiling to `adder_conv.l1_gemm` so the two kernels differ only in
the similarity op — exactly the comparison the paper's hardware section
makes (multiplier+tree vs 2-adders+tree).  On a real TPU this variant is the
MXU path (`jnp.dot` inside the tile); the adder variant is the VPU path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .adder_conv import _pad_to


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # The MXU-shaped tile op: contraction instead of abs-diff reduction.
    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128, bk: int = 128,
           bn: int = 128) -> jnp.ndarray:
    """Tiled Pallas GEMM: out = a @ b (the CNN baseline kernel)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    bk = min(bk, max(8, k))
    ap = _pad_to(a, bm, bk, 0.0)
    bp = _pad_to(b, bk, bn, 0.0)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def mult_conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                padding: str = "SAME", **tiles) -> jnp.ndarray:
    """CNN conv built on the Pallas GEMM (im2col outside the kernel)."""
    kh, kw, cin, cout = w.shape
    pats = ref.im2col(x, kh, kw, stride, padding)
    b, ho, wo, k = pats.shape
    out = matmul(pats.reshape(-1, k), w.reshape(k, cout), **tiles)
    return out.reshape(b, ho, wo, cout)
