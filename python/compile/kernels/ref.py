"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness ground truth: pytest (with hypothesis sweeps over
shapes and dtypes) asserts the Pallas kernels in `adder_conv.py`,
`mult_conv.py` and `quant.py` match these to within dtype tolerance, and the
Rust functional simulator (`rust/src/sim/functional.rs`) is validated against
HLO graphs lowered from these same functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def l1_gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Negative-L1-distance GEMM: out[m, n] = -sum_k |a[m, k] - b[k, n]|.

    This is the AdderNet similarity (Eq. 1 with S = -|F - W|) expressed in
    the im2col/GEMM form every conv below reduces to.
    """
    # (M, K, 1) - (1, K, N) -> (M, K, N); reduce K.
    return -jnp.sum(jnp.abs(a[:, :, None] - b[None, :, :]), axis=1)


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain GEMM oracle for the multiply-kernel baseline."""
    return jnp.matmul(a, b, preferred_element_type=a.dtype)


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1,
           padding: str = "VALID") -> jnp.ndarray:
    """Extract conv patches: x (B,H,W,C) -> (B, Ho, Wo, kh*kw*C).

    Patch feature order is (kh, kw, C) row-major, matching the weight
    reshape in the conv wrappers and the Rust functional simulator.
    """
    b, h, w, c = x.shape
    pats = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches returns features ordered (C, kh, kw);
    # transpose to (kh, kw, C) so weights reshape naturally.
    bo, ho, wo, f = pats.shape
    pats = pats.reshape(bo, ho, wo, c, kh, kw)
    pats = pats.transpose(0, 1, 2, 4, 5, 3)
    return pats.reshape(bo, ho, wo, kh * kw * c)


def adder_conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                     padding: str = "SAME") -> jnp.ndarray:
    """AdderNet convolution oracle.

    x: (B, H, W, Cin); w: (kh, kw, Cin, Cout).
    out[b,h,w,co] = -sum_{ky,kx,ci} |x_patch - w|   (Eq. 1, S = -|F-W|).
    """
    kh, kw, cin, cout = w.shape
    pats = im2col(x, kh, kw, stride, padding)
    b, ho, wo, k = pats.shape
    out = l1_gemm_ref(pats.reshape(-1, k), w.reshape(k, cout))
    return out.reshape(b, ho, wo, cout)


def mult_conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                    padding: str = "SAME") -> jnp.ndarray:
    """Standard convolution oracle via the same im2col path."""
    kh, kw, cin, cout = w.shape
    pats = im2col(x, kh, kw, stride, padding)
    b, ho, wo, k = pats.shape
    out = matmul_ref(pats.reshape(-1, k), w.reshape(k, cout))
    return out.reshape(b, ho, wo, cout)


# ---------------------------------------------------------------------------
# Shared-scaling-factor quantization (paper §3.1)
# ---------------------------------------------------------------------------

def shared_scale_exp(max_abs: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Power-of-two shared scale exponent e with s = 2^e.

    Chosen so that qmax * 2^e >= max_abs, i.e. the clip region covers the
    joint feature+weight range (Fig. 3c).
    """
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.ceil(jnp.log2(jnp.maximum(max_abs, 1e-12) / qmax))


def quantize_ref(x: jnp.ndarray, exp: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric quantization to signed `bits` with scale 2^exp.

    Returns integers held in the input float dtype (simulated quantization),
    matching what the int datapath of the FPGA functional model computes.
    """
    qmax = float(2 ** (bits - 1) - 1)
    s = jnp.exp2(exp)
    return jnp.clip(jnp.round(x / s), -qmax, qmax)


def dequantize_ref(q: jnp.ndarray, exp: jnp.ndarray) -> jnp.ndarray:
    return q * jnp.exp2(exp)


def fake_quant_ref(x: jnp.ndarray, exp: jnp.ndarray, bits: int) -> jnp.ndarray:
    """quantize -> dequantize round trip (the QAT / eval-sim primitive)."""
    return dequantize_ref(quantize_ref(x, exp, bits), exp)


def adder_conv2d_quant_ref(x, w, exp, bits, stride=1, padding="SAME"):
    """Quantized AdderNet conv with ONE shared scale (the paper's method).

    Because -|a-b| is 1-homogeneous, a single shared scale factors out of
    the whole sum: conv(q(x), q(w)) * s == quantized conv output.  This is
    exactly why the hardware needs no point alignment.
    """
    xq = quantize_ref(x, exp, bits)
    wq = quantize_ref(w, exp, bits)
    return adder_conv2d_ref(xq, wq, stride, padding) * jnp.exp2(exp)
