"""Layer-1 Pallas kernel: shared-scaling-factor quantization (paper §3.1).

A single power-of-two scale `2^exp` is shared between features and weights
so the integer adder datapath needs no point alignment — the kernel is a
pure elementwise clip/round, tiled over VMEM-sized blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, o_ref, *, exp: float, bits: int):
    qmax = float(2 ** (bits - 1) - 1)
    s = 2.0 ** exp
    o_ref[...] = jnp.clip(jnp.round(x_ref[...] / s), -qmax, qmax)


@functools.partial(jax.jit, static_argnames=("exp", "bits", "block"))
def quantize(x: jnp.ndarray, exp: float, bits: int,
             block: int = 65536) -> jnp.ndarray:
    """Symmetric quantize a flat-able tensor with scale 2^exp.

    Returns "integers" carried in the float dtype (simulated quantization),
    matching the FPGA functional model's int datapath inputs.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        flat = jnp.pad(flat, (0, pad))
    grid = (flat.shape[0] // blk,)
    out = pl.pallas_call(
        functools.partial(_quant_kernel, exp=float(exp), bits=int(bits)),
        grid=grid,
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=True,
    )(flat)
    return out[:n].reshape(shape)


def fake_quant(x: jnp.ndarray, exp: float, bits: int) -> jnp.ndarray:
    """quantize -> dequantize round trip through the Pallas kernel."""
    return quantize(x, exp, bits) * (2.0 ** float(exp))
