"""AOT compile path: lower Layer-2 graphs to HLO **text** + manifest.

Run once by ``make artifacts``; Python never appears on the Rust request
path.  HLO text (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly.

Outputs in --out-dir:
  * ``<graph>.hlo.txt``        one per exported graph
  * ``<arch>_init.bin``        initial parameters, flat f32 little-endian,
                               concatenated in sorted-name order
  * ``manifest.json``          every graph's input/output signature, the
                               parameter layout, and training hyper-params —
                               the single source of truth the Rust
                               coordinator loads.

Parameter ordering contract: JAX flattens dict pytrees in sorted-key
order; the manifest records that same sorted order, so Rust can treat the
whole state as an opaque ordered list of buffers.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import layers, model
from .kernels import adder_conv, mult_conv

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "s32"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": DTYPE_NAMES[jnp.asarray(x).dtype
                                                         if not hasattr(x, "dtype") else x.dtype]}


def _tree_specs(tree, prefix: str) -> List[dict]:
    leaves, _ = jax.tree_util.tree_flatten(tree)
    names = sorted(tree.keys()) if isinstance(tree, dict) else None
    out = []
    for i, leaf in enumerate(leaves):
        name = f"{prefix}/{names[i]}" if names else f"{prefix}[{i}]"
        d = _spec_of(leaf)
        d["name"] = name
        out.append(d)
    return out


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def write_init_bin(params: Dict[str, jnp.ndarray], path: str) -> List[dict]:
    """Write params to a flat f32 .bin (sorted-name order); return layout."""
    layout, off = [], 0
    with open(path, "wb") as f:
        for name in sorted(params.keys()):
            arr = np.asarray(params[name], dtype=np.float32)
            f.write(arr.tobytes())
            layout.append({"name": name, "shape": list(arr.shape),
                           "offset": off, "size": int(arr.size)})
            off += arr.size
    return layout


def export_model_graphs(arch: str, kernel: str, out_dir: str, manifest: dict,
                        batch: int, total_steps: int, base_lr: float,
                        with_probe: bool) -> None:
    params = model.init_params(arch, seed=0)
    momenta = model.init_momenta(params)
    x = jax.ShapeDtypeStruct((batch, 32, 32, 1), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    step = jax.ShapeDtypeStruct((), jnp.int32)

    init_file = f"{arch}_init.bin"
    if arch not in manifest["params"]:
        layout = write_init_bin(params, os.path.join(out_dir, init_file))
        manifest["params"][arch] = {
            "init_file": init_file,
            "layout": layout,
            "trainable": [n for n in sorted(params) if model.is_trainable(n)],
        }

    def emit(graph_name: str, lowered, kind: str, extra=None):
        fname = f"{graph_name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.tree_util.tree_leaves(lowered.out_info)
        entry = {
            "file": fname, "kind": kind, "arch": arch, "kernel": kernel,
            "batch": batch,
            "outputs": [{"shape": list(o.shape),
                         "dtype": DTYPE_NAMES[o.dtype]} for o in out_avals],
        }
        entry.update(extra or {})
        manifest["graphs"][graph_name] = entry
        print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)")

    name = f"{arch}_{kernel}"
    print(f"[aot] {name} (batch={batch})")

    train_fn = model.make_train_step(arch, kernel, base_lr=base_lr,
                                     total_steps=total_steps)
    lowered = jax.jit(train_fn).lower(
        _abstract(params), _abstract(momenta), x, y, step)
    emit(f"{name}_train", lowered, "train", {
        "total_steps": total_steps, "base_lr": base_lr,
        "n_params": len(params), "n_momenta": len(momenta),
        "input_order": (["params/" + n for n in sorted(params)]
                        + ["momenta/" + n for n in sorted(momenta)]
                        + ["x", "y", "step"]),
        "output_order": (["params/" + n for n in sorted(params)]
                         + ["momenta/" + n for n in sorted(momenta)]
                         + ["loss", "acc"]),
    })

    eval_fn = model.make_eval_step(arch, kernel)
    lowered = jax.jit(eval_fn).lower(_abstract(params), x)
    emit(f"{name}_eval", lowered, "eval", {
        "n_params": len(params),
        "input_order": ["params/" + n for n in sorted(params)] + ["x"],
        "output_order": ["logits"],
    })

    if with_probe:
        probe_fn = model.make_probe(arch, kernel)
        lowered = jax.jit(probe_fn).lower(_abstract(params), x)
        emit(f"{name}_probe", lowered, "probe", {
            "n_params": len(params),
            "layers": model.probe_layer_names(arch),
            "input_order": ["params/" + n for n in sorted(params)] + ["x"],
        })


def export_kernel_demos(out_dir: str, manifest: dict) -> None:
    """Small standalone kernel graphs: Rust cargo tests cross-validate the
    bit-accurate functional simulator against exactly these HLO modules."""
    m, k, n = 16, 32, 8
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    for gname, fn in (
        ("l1gemm_demo", lambda a, b: adder_conv.l1_gemm(a, b, bm=16, bk=16,
                                                        bn=8)),
        ("matmul_demo", lambda a, b: mult_conv.matmul(a, b, bm=16, bk=16,
                                                      bn=8)),
    ):
        lowered = jax.jit(fn).lower(a, b)
        fname = f"{gname}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["graphs"][gname] = {
            "file": fname, "kind": "kernel_demo", "m": m, "k": k, "n": n,
            "input_order": ["a", "b"], "output_order": ["out"],
            "outputs": [{"shape": [m, n], "dtype": "f32"}],
        }
        print(f"  wrote {fname}")


# Default export set: every kernel on LeNet-5 (Fig. 2/5 workloads), adder &
# mult on the ResNet (Fig. 2/3 workloads), probes for the adder models
# (Fig. 3a/b).  --full adds resnet20.
DEFAULT_SET = [
    ("lenet5", "adder", True),
    ("lenet5", "mult", False),
    ("lenet5", "shift", False),
    ("lenet5", "xnor", False),
    ("resnet8", "adder", True),
    ("resnet8", "mult", False),
]
FULL_EXTRA = [
    ("resnet20", "adder", True),
    ("resnet20", "mult", False),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--total-steps", type=int, default=400)
    ap.add_argument("--base-lr", type=float, default=0.1)
    ap.add_argument("--impl", choices=("pallas", "ref"), default="pallas",
                    help="adder-conv forward implementation in the graphs")
    ap.add_argument("--full", action="store_true",
                    help="also export resnet20 graphs")
    args = ap.parse_args()

    layers.set_impl(args.impl)
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"graphs": {}, "params": {},
                "impl": args.impl, "batch": args.batch}
    export_kernel_demos(args.out_dir, manifest)
    todo = list(DEFAULT_SET) + (FULL_EXTRA if args.full else [])
    for arch, kernel, probe in todo:
        export_model_graphs(arch, kernel, args.out_dir, manifest,
                            args.batch, args.total_steps, args.base_lr,
                            probe)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest with {len(manifest['graphs'])} graphs written")


if __name__ == "__main__":
    main()
